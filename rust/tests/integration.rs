//! Cross-module integration tests: engine-vs-engine numeric equivalence,
//! distributed-vs-serial equivalence, coordinator flows, and partitioner
//! → local-view → training consistency on real (scaled) datasets.

use morphling::baselines::{GatherScatterEngine, NonFusedEngine};
use morphling::coordinator::{run, TrainSpec};
use morphling::dist::runtime::{train_distributed, DistConfig, PartitionerKind};
use morphling::dist::NetworkModel;
use morphling::engine::native::NativeEngine;
use morphling::engine::sparsity::SparsityPolicy;
use morphling::engine::{Engine, Mask};
use morphling::graph::datasets;
use morphling::kernels::update::AdamParams;
use morphling::model::{Arch, ModelConfig};
use morphling::optim::OptKind;

/// All three native-path engines implement the same GCN: given one seed,
/// their per-epoch losses must agree to float tolerance on a real dataset.
#[test]
fn engines_numerically_equivalent_on_corafull() {
    let ds = datasets::load_by_name("corafull").unwrap();
    let config = ModelConfig::paper_default(Arch::Gcn, ds.spec.features, ds.spec.classes);
    let mut native = NativeEngine::new(
        &ds,
        &config,
        OptKind::Adam,
        AdamParams::default(),
        SparsityPolicy::paper_default(), // sparse path (s=0.95)
        7,
    );
    let mut gs = GatherScatterEngine::paper_default(&ds, 7);
    let mut nf = NonFusedEngine::paper_default(&ds, 7);
    for e in 0..2 {
        let a = native.train_epoch(&ds).loss;
        let b = gs.train_epoch(&ds).loss;
        let c = nf.train_epoch(&ds).loss;
        assert!((a - b).abs() < 5e-3, "epoch {e}: native {a} vs gs {b}");
        assert!((a - c).abs() < 5e-3, "epoch {e}: native {a} vs nf {c}");
    }
}

/// Distributed (2 ranks) and serial training produce the same loss curve.
#[test]
fn distributed_equals_serial_on_ogbn_arxiv() {
    let ds = datasets::load_by_name("ogbn-arxiv").unwrap();
    let cfg = DistConfig {
        world: 2,
        epochs: 3,
        network: NetworkModel::ideal(),
        seed: 11,
        ..Default::default()
    };
    let dist = train_distributed(&ds, &cfg).expect("dist run");
    let config = ModelConfig::paper_default(Arch::Gcn, ds.spec.features, ds.spec.classes);
    let mut serial = NativeEngine::new(
        &ds,
        &config,
        OptKind::Adam,
        AdamParams::default(),
        SparsityPolicy::from_tau(1.01), // dist runtime is dense-path
        11,
    );
    for e in 0..3 {
        let s = serial.train_epoch(&ds).loss;
        assert!(
            (dist.losses[e] - s).abs() < 5e-3,
            "epoch {e}: dist {} vs serial {s}",
            dist.losses[e]
        );
    }
}

/// The coordinator picks the sparse path for NELL (99.2% sparse) and the
/// dense path for Reddit (dense features) — the paper's §V-C dispatch.
#[test]
fn coordinator_dispatch_matches_paper() {
    for (name, expect) in [("nell", "sparse"), ("ogbn-arxiv", "dense")] {
        let out = run(&TrainSpec {
            dataset: name.to_string(),
            epochs: 1,
            ..Default::default()
        })
        .unwrap();
        assert_eq!(out.mode, expect, "{name}");
    }
}

/// Hierarchical partitioner → LocalView construction over every dataset
/// (structure invariants at dataset scale).
#[test]
fn partition_views_consistent_on_flickr() {
    let ds = datasets::load_by_name("flickr").unwrap();
    let r = morphling::partition::hierarchical_partition(&ds.raw_graph, 4, 3);
    r.partitioning.validate(ds.spec.nodes).unwrap();
    let views = morphling::dist::g2l::build_views(&ds.graph, &r.partitioning);
    let total_local: usize = views.iter().map(|v| v.n_local()).sum();
    assert_eq!(total_local, ds.spec.nodes);
    let total_edges: usize = views.iter().map(|v| v.graph.num_edges()).sum();
    assert_eq!(total_edges, ds.graph.num_edges());
}

/// Training for real epochs on a mid-size dataset reaches useful accuracy
/// (the labels are graph-smoothed projections — learnable by design).
#[test]
fn native_reaches_signal_on_flickr() {
    let ds = datasets::load_by_name("flickr").unwrap();
    let mut eng = NativeEngine::paper_default(&ds, Arch::Gcn, 5);
    let first = eng.train_epoch(&ds).loss;
    for _ in 0..40 {
        eng.train_epoch(&ds);
    }
    let (_, acc) = eng.evaluate(&ds, Mask::Test);
    let last = eng.train_epoch(&ds).loss;
    assert!(last < first * 0.8, "{first} -> {last}");
    assert!(acc > 1.5 / ds.spec.classes as f64, "test acc {acc}");
}

/// SAGE-max (Listing 1's configuration) trains end to end via the
/// coordinator.
#[test]
fn sage_max_listing1_flow() {
    let out = run(&TrainSpec {
        dataset: "ppi".to_string(),
        arch: Arch::SageMax,
        epochs: 5,
        ..Default::default()
    })
    .unwrap();
    assert!(out.report.final_loss() < out.report.epochs[0].loss);
}

/// Memory ordering across engines holds on a dense mid-size dataset:
/// gather-scatter > nonfused > native (Table III's structural claim).
#[test]
fn memory_ordering_on_ogbn_arxiv() {
    let ds = datasets::load_by_name("ogbn-arxiv").unwrap();
    let mut native = NativeEngine::paper_default(&ds, Arch::Gcn, 1);
    let mut gs = GatherScatterEngine::paper_default(&ds, 1);
    let mut nf = NonFusedEngine::paper_default(&ds, 1);
    native.train_epoch(&ds);
    gs.train_epoch(&ds);
    nf.train_epoch(&ds);
    let (a, b, c) = (native.peak_bytes(), gs.peak_bytes(), nf.peak_bytes());
    assert!(b > c, "gs {b} should exceed nonfused {c}");
    assert!(b > 2 * a, "gs {b} should dwarf native {a}");
}
