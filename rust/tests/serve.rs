//! Serving-subsystem invariants (ISSUE 8 acceptance criteria):
//!
//! 1. **Fresh-snapshot exactness** — snapshot-served logits are
//!    bitwise-identical to the exact full-neighborhood recursion for
//!    GCN / SAGE-mean / SAGE-max, and reproduce `MiniBatchEngine`'s
//!    `evaluate()` loss/accuracy to the last bit;
//! 2. **100% deep-layer hit-rate** — snapshot mode answers every deep
//!    source row from the frozen store and materializes strictly fewer
//!    edges than exact mode;
//! 3. **Worker-count determinism** — served logits depend only on
//!    (snapshot version, target batch), not on how many server workers
//!    raced over the queue;
//! 4. **No torn reads** — under concurrent snapshot swaps, every response
//!    matches exactly one snapshot version's serial output.

use morphling::engine::{Engine, Mask};
use morphling::graph::datasets;
use morphling::kernels::activations::softmax_xent;
use morphling::kernels::parallel::ExecPolicy;
use morphling::model::Arch;
use morphling::sampler::{MiniBatchConfig, MiniBatchEngine, SamplerScratch};
use morphling::serve::{ServeJob, ServeMode, Server, ServerConfig, ServingSnapshot, SnapshotSlot};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

fn tiny_spec() -> morphling::graph::DatasetSpec {
    morphling::graph::DatasetSpec {
        name: "tiny-serve-it",
        real_nodes: 0,
        real_edges: 0,
        real_features: 0,
        nodes: 230,
        edges: 1500,
        features: 40,
        classes: 5,
        feat_sparsity: 0.0,
        gamma: 2.4,
        components: 1,
    }
}

/// Train a small engine for `epochs` and freeze a snapshot of it.
fn trained_snapshot(
    ds: &morphling::graph::Dataset,
    arch: Arch,
    epochs: usize,
    version: u64,
) -> ServingSnapshot {
    let cfg = MiniBatchConfig {
        batch_size: ds.spec.nodes, // evaluate() runs as a single batch
        fanouts: vec![3, 5],
        prefetch: false,
        cache: None,
    };
    let mut eng = MiniBatchEngine::paper_default(ds, arch, cfg, 17)
        .expect("sampled-mode arch must construct");
    for _ in 0..epochs {
        eng.train_epoch(ds);
    }
    ServingSnapshot::build(ds, eng.params().clone(), 0, 17, version, ExecPolicy::serial())
        .expect("snapshot build over a sampled-mode arch must succeed")
}

/// Ascending ids selected by a mask.
fn mask_ids(mask: &[bool]) -> Vec<u32> {
    mask.iter()
        .enumerate()
        .filter(|(_, &m)| m)
        .map(|(u, _)| u as u32)
        .collect()
}

#[test]
fn snapshot_serving_is_bitwise_exact_per_arch() {
    let ds = datasets::load(&tiny_spec());
    for arch in [Arch::Gcn, Arch::SageMean, Arch::SageMax] {
        let snap = trained_snapshot(&ds, arch, 2, 1);
        let mut scratch = SamplerScratch::new(ds.spec.nodes);
        let targets = mask_ids(&ds.val_mask);
        assert!(!targets.is_empty(), "tiny dataset must have val nodes");

        let served = snap.serve(&targets, ServeMode::Snapshot, &mut scratch);
        let exact = snap.serve(&targets, ServeMode::Exact, &mut scratch);

        // 1. bitwise-identical logits on a fresh snapshot
        assert_eq!(served.logits.rows, targets.len());
        assert_eq!(
            served.logits.data, exact.logits.data,
            "{arch:?}: snapshot-served logits must be bitwise-exact"
        );
        // 2. every deep row answered from the store, and strictly less work
        assert!(served.cache_candidates > 0, "{arch:?}: deep rows must exist");
        assert_eq!(served.cache_hits, served.cache_candidates);
        assert_eq!(served.hit_rate(), 1.0, "{arch:?}: deep-layer hit-rate must be 100%");
        assert_eq!(exact.cache_hits, 0, "exact mode never consults the store");
        assert!(
            served.sampled_edges < exact.sampled_edges,
            "{arch:?}: snapshot mode must materialize fewer edges ({} vs {})",
            served.sampled_edges,
            exact.sampled_edges
        );
    }
}

#[test]
fn snapshot_serving_reproduces_engine_evaluation() {
    let ds = datasets::load(&tiny_spec());
    let cfg = MiniBatchConfig {
        batch_size: ds.spec.nodes,
        fanouts: vec![3, 5],
        prefetch: false,
        cache: None,
    };
    let mut eng = MiniBatchEngine::paper_default(&ds, Arch::SageMean, cfg, 17)
        .expect("sampled-mode arch must construct");
    for _ in 0..2 {
        eng.train_epoch(&ds);
    }
    let (eval_loss, eval_acc) = eng.evaluate(&ds, Mask::Val);

    let snap = ServingSnapshot::build(&ds, eng.params().clone(), 0, 17, 1, ExecPolicy::serial())
        .expect("snapshot build must succeed");
    let targets = mask_ids(&ds.val_mask);
    let mut scratch = SamplerScratch::new(ds.spec.nodes);
    let served = snap.serve(&targets, ServeMode::Snapshot, &mut scratch);

    // Same rows, same labels, same mask, same reduction arithmetic as the
    // engine's single-batch evaluate() — bit-equality, not tolerance.
    let labels: Vec<u32> = targets.iter().map(|&g| ds.labels[g as usize]).collect();
    let all = vec![true; targets.len()];
    let (l, a, n) = softmax_xent(&served.logits, &labels, &all, None);
    assert_eq!(n, targets.len());
    let loss = (l * n as f64) / n as f64;
    let acc = (a * n as f64) / n as f64;
    assert_eq!(loss, eval_loss, "served loss must equal evaluate() exactly");
    assert_eq!(acc, eval_acc, "served accuracy must equal evaluate() exactly");
}

#[test]
fn served_logits_invariant_across_worker_counts() {
    let ds = datasets::load(&tiny_spec());
    let snap = trained_snapshot(&ds, Arch::SageMean, 1, 1);
    // A deterministic request stream: disjoint-ish target batches.
    let requests: Vec<Vec<u32>> = (0..8u32)
        .map(|i| {
            let mut t: Vec<u32> = (0..16u32)
                .map(|j| (i * 13 + j * 7) % ds.spec.nodes as u32)
                .collect();
            t.sort_unstable();
            t.dedup();
            t
        })
        .collect();
    let mut per_workers: Vec<Vec<Vec<f32>>> = Vec::new();
    for workers in [1usize, 4] {
        let slot = Arc::new(SnapshotSlot::new(snap.clone()));
        let server = Server::start(
            Arc::clone(&slot),
            &ServerConfig {
                workers,
                queue_cap: 2,
                mode: ServeMode::Snapshot,
            },
        );
        for (i, t) in requests.iter().enumerate() {
            assert!(server.submit(ServeJob {
                id: i as u64,
                targets: t.clone(),
            }));
        }
        let results = server.finish();
        assert_eq!(results.len(), requests.len());
        per_workers.push(
            results
                .into_iter()
                .map(|r| {
                    assert_eq!(r.response.version, 1);
                    r.response.logits.data
                })
                .collect(),
        );
    }
    assert_eq!(
        per_workers[0], per_workers[1],
        "served logits must be bitwise-invariant across worker counts"
    );
}

#[test]
fn snapshot_swap_never_tears_responses() {
    let ds = datasets::load(&tiny_spec());
    // Two versions with genuinely different parameters.
    let v1 = trained_snapshot(&ds, Arch::SageMean, 1, 1);
    let v2 = trained_snapshot(&ds, Arch::SageMean, 2, 2);
    let requests: Vec<Vec<u32>> = (0..24u32)
        .map(|i| {
            let mut t: Vec<u32> = (0..12u32)
                .map(|j| (i * 11 + j * 5) % ds.spec.nodes as u32)
                .collect();
            t.sort_unstable();
            t.dedup();
            t
        })
        .collect();
    // Serial ground truth per (version, request).
    let mut scratch = SamplerScratch::new(ds.spec.nodes);
    let expect_v1: Vec<Vec<f32>> = requests
        .iter()
        .map(|t| v1.serve(t, ServeMode::Snapshot, &mut scratch).logits.data)
        .collect();
    let expect_v2: Vec<Vec<f32>> = requests
        .iter()
        .map(|t| v2.serve(t, ServeMode::Snapshot, &mut scratch).logits.data)
        .collect();

    let slot = Arc::new(SnapshotSlot::new(v1.clone()));
    let server = Server::start(
        Arc::clone(&slot),
        &ServerConfig {
            workers: 4,
            queue_cap: 2,
            mode: ServeMode::Snapshot,
        },
    );
    let stop = Arc::new(AtomicBool::new(false));
    let swapper = {
        let slot = Arc::clone(&slot);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut flip = false;
            while !stop.load(Ordering::Relaxed) {
                slot.swap(if flip { v1.clone() } else { v2.clone() });
                flip = !flip;
                std::thread::yield_now();
            }
        })
    };
    for (i, t) in requests.iter().enumerate() {
        assert!(server.submit(ServeJob {
            id: i as u64,
            targets: t.clone(),
        }));
    }
    let results = server.finish();
    stop.store(true, Ordering::Relaxed);
    swapper.join().expect("swapper thread panicked");

    assert_eq!(results.len(), requests.len());
    for r in &results {
        let id = r.id as usize;
        let expected = match r.response.version {
            1 => &expect_v1[id],
            2 => &expect_v2[id],
            v => panic!("response carries unknown snapshot version {v}"),
        };
        assert_eq!(
            &r.response.logits.data, expected,
            "request {id}: response must match its snapshot version (v{}) bit-for-bit",
            r.response.version
        );
    }
}
