//! Crash-consistency acceptance suite (checkpoint/restore PR):
//!
//! 1. **Save/load identity** — a checkpoint round-trips bitwise: params,
//!    optimizer moments/step, epoch cursor, seed, and the historical-cache
//!    stores all survive the on-disk format unchanged.
//! 2. **Corruption is detected and named** — a truncated or bit-flipped
//!    file is rejected with a message naming the file and the damaged
//!    field, and `latest_good` falls back to the previous good checkpoint.
//! 3. **Bitwise resume** — killing a run at *every* epoch boundary and
//!    resuming from the newest checkpoint yields final parameters
//!    bit-identical to a run that never crashed, across
//!    GCN/SAGE-mean/SAGE-max × threads {1, 4} (serial mini-batch, cache
//!    on for SAGE-mean) and across the world-2 sampled distributed
//!    runtime. This is the crash-consistency contract: because the
//!    shuffle RNG is epoch-keyed, (params, opt state, epoch cursor,
//!    cache stores) fully determine the remaining epochs.

use morphling::ckpt::{corrupt_payload_byte, CkptStore};
use morphling::dist::runtime::{train_distributed, DistConfig, DistMode};
use morphling::engine::Engine;
use morphling::fault::FaultPlan;
use morphling::graph::{datasets, Dataset};
use morphling::kernels::update::AdamParams;
use morphling::model::{Arch, ModelConfig};
use morphling::optim::OptKind;
use morphling::sampler::{MiniBatchConfig, MiniBatchEngine};
use morphling::train::{train, CkptPolicy, TrainConfig};
use std::path::PathBuf;

fn tiny_dataset() -> Dataset {
    let spec = morphling::graph::DatasetSpec {
        name: "tiny-ckpt-it",
        real_nodes: 0,
        real_edges: 0,
        real_features: 0,
        nodes: 220,
        edges: 1400,
        features: 40,
        classes: 4,
        feat_sparsity: 0.0,
        gamma: 2.4,
        components: 1,
    };
    datasets::load(&spec)
}

/// A fresh per-test checkpoint directory under the system temp dir.
fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("morphling-ckpt-it-{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

const SEED: u64 = 77;

/// Build the engine every leg of a comparison uses: identical seed and
/// config, so divergence can only come from the checkpoint path.
fn make_engine(ds: &Dataset, arch: Arch, threads: usize, cache: Option<u64>) -> MiniBatchEngine {
    let config = ModelConfig::paper_default(arch, ds.spec.features, ds.spec.classes);
    let mb = MiniBatchConfig {
        batch_size: 64,
        fanouts: vec![4, 4],
        prefetch: false,
        cache,
    };
    let mut eng = MiniBatchEngine::new(ds, &config, OptKind::Adam, AdamParams::default(), mb, SEED)
        .expect("tiny dataset satisfies the mini-batch constructor");
    eng.set_threads(threads);
    eng
}

#[test]
fn checkpoint_roundtrip_is_bitwise_identity() {
    let ds = tiny_dataset();
    let mut eng = make_engine(&ds, Arch::SageMean, 1, Some(2));
    for _ in 0..2 {
        eng.train_epoch(&ds);
    }
    let ck = eng.export_ckpt().expect("mini-batch engine supports checkpointing");
    let dir = fresh_dir("roundtrip");
    let store = CkptStore::new(&dir).expect("temp checkpoint dir must open");
    store.save(&ck).expect("save must succeed");
    let scan = store.latest_good();
    assert!(scan.skipped.is_empty(), "no file may be skipped: {:?}", scan.skipped);
    let (path, loaded) = scan.found.expect("the just-saved checkpoint must load");
    assert_eq!(path, store.path_for(ck.epoch));
    assert_eq!(loaded.epoch, ck.epoch);
    assert_eq!(loaded.seed, ck.seed);
    assert_eq!(
        loaded.params.param_hash(),
        ck.params.param_hash(),
        "parameter bits must survive the round trip"
    );
    assert_eq!(loaded.opt, ck.opt, "optimizer moments/step must round-trip");
    assert_eq!(loaded.caches.len(), ck.caches.len());
    for (a, b) in loaded.caches.iter().zip(&ck.caches) {
        assert_eq!(a.staleness(), b.staleness());
        assert_eq!(a.num_levels(), b.num_levels());
        for l in 0..a.num_levels() {
            let (ma, sa) = a.level_data(l);
            let (mb, sb) = b.level_data(l);
            assert_eq!(sa, sb, "stamps at level {l}");
            assert_eq!(ma.data, mb.data, "rows at level {l}");
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corruption_is_rejected_by_name_and_falls_back() {
    let ds = tiny_dataset();
    let mut eng = make_engine(&ds, Arch::Gcn, 1, None);
    eng.train_epoch(&ds);
    let mut ck = eng.export_ckpt().expect("mini-batch engine supports checkpointing");
    let dir = fresh_dir("corrupt");
    let store = CkptStore::new(&dir).expect("temp checkpoint dir must open");
    ck.epoch = 1;
    store.save(&ck).expect("epoch-1 save");
    ck.epoch = 2;
    store.save(&ck).expect("epoch-2 save");

    // Bit-flip the newest file's payload: the loader must name the file
    // and the damaged field, and the scan must fall back to epoch 1.
    let newest = store.path_for(2);
    corrupt_payload_byte(&newest).expect("flip one payload byte");
    let err = CkptStore::load_path(&newest).expect_err("flipped payload must be rejected");
    assert!(
        err.contains(&newest.display().to_string()),
        "error must name the file: {err}"
    );
    assert!(err.contains("CRC mismatch"), "error must say what failed: {err}");
    let scan = store.latest_good();
    let (path, good) = scan.found.expect("epoch-1 checkpoint is still good");
    assert_eq!(path, store.path_for(1));
    assert_eq!(good.epoch, 1);
    assert_eq!(scan.skipped.len(), 1, "the flipped file is skipped with a reason");
    assert!(scan.skipped[0].contains("CRC mismatch"), "{:?}", scan.skipped);

    // Truncation: chop the file mid-payload; the rejection names the
    // field the cursor ran out inside.
    let bytes = std::fs::read(store.path_for(1)).expect("read good checkpoint");
    std::fs::write(&newest, &bytes[..bytes.len() / 2]).expect("write truncated file");
    let err = CkptStore::load_path(&newest).expect_err("truncated file must be rejected");
    assert!(
        err.contains("truncated") || err.contains("payload"),
        "error must describe the damage: {err}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Run `epochs` epochs uninterrupted and return the final param hash.
fn uninterrupted_hash(ds: &Dataset, arch: Arch, threads: usize, cache: Option<u64>) -> u64 {
    let mut eng = make_engine(ds, arch, threads, cache);
    let r = train(
        &mut eng,
        ds,
        &TrainConfig {
            epochs: 4,
            eval_every: 0,
            ..Default::default()
        },
    );
    assert!(!r.killed);
    eng.gnn_params().expect("mini-batch engine exposes params").param_hash()
}

/// Kill at `kill_epoch`, then resume from the newest checkpoint and
/// finish; return the final param hash.
fn crash_resume_hash(
    ds: &Dataset,
    arch: Arch,
    threads: usize,
    cache: Option<u64>,
    kill_epoch: u64,
    dir: &PathBuf,
) -> u64 {
    let store = CkptStore::new(dir).expect("temp checkpoint dir must open");
    let mut eng = make_engine(ds, arch, threads, cache);
    let r = train(
        &mut eng,
        ds,
        &TrainConfig {
            epochs: 4,
            eval_every: 0,
            ckpt: Some(CkptPolicy {
                store: CkptStore::new(dir).expect("reopen"),
                every: 1,
                seed: SEED,
            }),
            fault: FaultPlan::parse(&format!("kill@epoch={kill_epoch}")).expect("fault grammar"),
            ..Default::default()
        },
    );
    assert!(r.killed, "the kill fault must fire at epoch {kill_epoch}");
    assert_eq!(r.ckpt_saves as u64, kill_epoch, "one checkpoint per completed epoch");
    drop(eng); // the "crashed" process

    let (_, ck) = store
        .latest_good()
        .found
        .expect("a checkpoint exists at every kill boundary");
    assert_eq!(ck.epoch, kill_epoch);
    let mut eng = make_engine(ds, arch, threads, cache);
    eng.import_ckpt(&ck).expect("restore must accept a matching checkpoint");
    let r = train(
        &mut eng,
        ds,
        &TrainConfig {
            epochs: 4,
            eval_every: 0,
            start_epoch: ck.epoch as usize,
            ..Default::default()
        },
    );
    assert!(!r.killed);
    assert_eq!(r.epochs.len(), 4 - kill_epoch as usize);
    eng.gnn_params().expect("mini-batch engine exposes params").param_hash()
}

#[test]
fn kill_at_every_boundary_resumes_bitwise_across_arch_and_threads() {
    let ds = tiny_dataset();
    // SAGE-mean runs with the historical cache on (staleness 2) so the
    // store round-trips through the checkpoint; the others run cache-off.
    let grid = [
        (Arch::Gcn, None),
        (Arch::SageMean, Some(2u64)),
        (Arch::SageMax, None),
    ];
    for (arch, cache) in grid {
        for threads in [1usize, 4] {
            let want = uninterrupted_hash(&ds, arch, threads, cache);
            for kill_epoch in 1..=3u64 {
                let dir = fresh_dir(&format!("grid-{arch:?}-{threads}-{kill_epoch}"));
                let got = crash_resume_hash(&ds, arch, threads, cache, kill_epoch, &dir);
                assert_eq!(
                    got, want,
                    "{arch:?} × {threads} threads, killed at epoch {kill_epoch}: \
                     resume must be bitwise-equal to the uninterrupted run"
                );
                let _ = std::fs::remove_dir_all(&dir);
            }
        }
    }
}

#[test]
fn dist_world2_kill_resume_is_bitwise() {
    let ds = tiny_dataset();
    let base = DistConfig {
        world: 2,
        epochs: 4,
        seed: SEED,
        mode: DistMode::Sampled,
        threads: 1,
        shards: 2,
        batch_size: 64,
        fanouts: vec![4, 4],
        cache: Some(2),
        ..Default::default()
    };
    let clean = train_distributed(&ds, &base).expect("uninterrupted dist run");
    assert!(!clean.killed);
    let want = clean.params.param_hash();

    for threads in [1usize, 4] {
        let dir = fresh_dir(&format!("dist-{threads}"));
        let crashed = train_distributed(
            &ds,
            &DistConfig {
                threads,
                ckpt_dir: Some(dir.display().to_string()),
                ckpt_every: 1,
                fault: FaultPlan::parse("kill@epoch=2").expect("fault grammar"),
                ..base.clone()
            },
        )
        .expect("crashed dist leg runs to the kill point");
        assert!(crashed.killed);
        assert_eq!(crashed.ckpt_saves, 2);

        let resumed = train_distributed(
            &ds,
            &DistConfig {
                threads,
                ckpt_dir: Some(dir.display().to_string()),
                resume: true,
                ..base.clone()
            },
        )
        .expect("resumed dist leg");
        assert!(!resumed.killed);
        assert_eq!(resumed.start_epoch, 2);
        assert_eq!(
            resumed.params.param_hash(),
            want,
            "world-2 crash→resume at {threads} kernel thread(s) must be bitwise-equal \
             to the uninterrupted run"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn resume_rejects_mismatched_engine_shape() {
    let ds = tiny_dataset();
    let mut eng = make_engine(&ds, Arch::Gcn, 1, None);
    eng.train_epoch(&ds);
    let ck = eng.export_ckpt().expect("export");
    // A SAGE-mean engine must refuse a GCN checkpoint, naming both.
    let mut other = make_engine(&ds, Arch::SageMean, 1, None);
    let err = other.import_ckpt(&ck).expect_err("arch mismatch must be rejected");
    assert!(err.contains("gcn") || err.contains("Gcn"), "{err}");
    // A cache-enabled engine must refuse a cache-less checkpoint.
    let mut cached = make_engine(&ds, Arch::Gcn, 1, Some(2));
    let err = cached.import_ckpt(&ck).expect_err("cache mismatch must be rejected");
    assert!(err.contains("cache"), "{err}");
}
