//! The kernel-variant contract (docs/KERNELS.md), pinned end to end:
//!
//! 1. every width-specialized body is **bitwise-identical** to its generic
//!    counterpart through the public `_ex` entry points, across the covered
//!    widths, an uncovered fallback width, and serial/threaded execution;
//! 2. full training epochs are bit-deterministic across variant choices
//!    (generic vs specialized vs auto) for GCN / SAGE-mean / SAGE-max at
//!    1 and 4 threads;
//! 3. a fixed tuning manifest yields stable dispatcher decisions, and a
//!    manifest survives a save → load round trip with identical decisions.

use morphling::engine::native::NativeEngine;
use morphling::engine::Engine;
use morphling::graph::datasets;
use morphling::graph::generator::{power_law_graph, GraphConfig};
use morphling::kernels::dispatch::{
    Dispatcher, InputStats, KernelVariant, Op, SizeBucket, TuneEntry, TuneManifest, VariantChoice,
};
use morphling::kernels::gemm::{gemm_a_bt_acc_ex, gemm_a_bt_ex, gemm_at_b_ex, gemm_ex};
use morphling::kernels::parallel::ExecPolicy;
use morphling::kernels::sparse_feat::{spmm_csc_t_dense_ex, spmm_csr_dense_ex};
use morphling::kernels::specialized;
use morphling::kernels::spmm::{spmm_max_ex, spmm_naive_ex, spmm_tiled_ex};
use morphling::model::Arch;
use morphling::tensor::{CscMatrix, CsrMatrix, Matrix};
use morphling::util::proptest::{random_matrix, random_sparse_matrix};
use morphling::util::Rng;

/// Covered widths plus one uncovered width (100 → generic fallback even
/// under ForceSpecialized), at serial and threaded execution.
const WIDTHS: [usize; 5] = [16, 32, 64, 128, 100];
const THREADS: [usize; 2] = [1, 4];

fn policies(threads: usize) -> (ExecPolicy, ExecPolicy, ExecPolicy) {
    let base = ExecPolicy::with_threads(threads);
    (
        base.with_variant(VariantChoice::ForceGeneric),
        base.with_variant(VariantChoice::ForceSpecialized),
        base.with_variant(VariantChoice::Auto),
    )
}

/// Every SpMM-family `_ex` entry produces bit-identical values (and argmax
/// provenance) under generic, specialized, and auto variants.
#[test]
fn spmm_family_bitwise_across_variants() {
    let mut rng = Rng::new(0xA11CE);
    let n = 400usize;
    let g = power_law_graph(
        &GraphConfig {
            num_nodes: n,
            num_edges: 3_200,
            power_law_gamma: 2.3,
            components: 1,
        },
        &mut rng,
    );
    for f in WIDTHS {
        let x = Matrix::from_vec(n, f, random_matrix(&mut rng, n, f));
        for t in THREADS {
            let (pg, ps, pa) = policies(t);
            let mut yg = Matrix::zeros(n, f);
            let mut ys = Matrix::zeros(n, f);
            let mut ya = Matrix::zeros(n, f);
            spmm_tiled_ex(&g, &x, &mut yg, pg);
            spmm_tiled_ex(&g, &x, &mut ys, ps);
            spmm_tiled_ex(&g, &x, &mut ya, pa);
            assert_eq!(yg.data, ys.data, "spmm_tiled F={f} t={t}");
            assert_eq!(yg.data, ya.data, "spmm_tiled auto F={f} t={t}");

            spmm_naive_ex(&g, &x, &mut yg, pg);
            spmm_naive_ex(&g, &x, &mut ys, ps);
            assert_eq!(yg.data, ys.data, "spmm_naive F={f} t={t}");

            let mut ag = vec![0u32; n * f];
            let mut as_ = vec![0u32; n * f];
            spmm_max_ex(&g, &x, &mut yg, &mut ag, pg);
            spmm_max_ex(&g, &x, &mut ys, &mut as_, ps);
            assert_eq!(yg.data, ys.data, "spmm_max values F={f} t={t}");
            assert_eq!(ag, as_, "spmm_max argmax F={f} t={t}");
        }
    }
}

/// The dense GEMM family is bit-identical across variants: `A·B` (output
/// width key), `Aᵀ·B` (output width key), and `A·Bᵀ` overwrite +
/// accumulate (inner width key).
#[test]
fn gemm_family_bitwise_across_variants() {
    let mut rng = Rng::new(0xB0B);
    let m = 150usize;
    for f in WIDTHS {
        let a = Matrix::from_vec(m, f, random_matrix(&mut rng, m, f));
        let w = Matrix::from_vec(f, f, random_matrix(&mut rng, f, f));
        let gr = Matrix::from_vec(m, f, random_matrix(&mut rng, m, f));
        let bt = Matrix::from_vec(48, f, random_matrix(&mut rng, 48, f));
        let seed = random_matrix(&mut rng, m, 48);
        for t in THREADS {
            let (pg, ps, _) = policies(t);
            let mut cg = Matrix::zeros(m, f);
            let mut cs = Matrix::zeros(m, f);
            gemm_ex(&a, &w, &mut cg, pg);
            gemm_ex(&a, &w, &mut cs, ps);
            assert_eq!(cg.data, cs.data, "gemm F={f} t={t}");

            let mut dwg = Matrix::zeros(f, f);
            let mut dws = Matrix::zeros(f, f);
            gemm_at_b_ex(&a, &gr, &mut dwg, pg);
            gemm_at_b_ex(&a, &gr, &mut dws, ps);
            assert_eq!(dwg.data, dws.data, "gemm_at_b F={f} t={t}");

            let mut dg = Matrix::zeros(m, 48);
            let mut dsp = Matrix::zeros(m, 48);
            gemm_a_bt_ex(&a, &bt, &mut dg, pg);
            gemm_a_bt_ex(&a, &bt, &mut dsp, ps);
            assert_eq!(dg.data, dsp.data, "gemm_a_bt F={f} t={t}");

            let mut accg = Matrix::from_vec(m, 48, seed.clone());
            let mut accs = Matrix::from_vec(m, 48, seed.clone());
            gemm_a_bt_acc_ex(&a, &bt, &mut accg, pg);
            gemm_a_bt_acc_ex(&a, &bt, &mut accs, ps);
            assert_eq!(accg.data, accs.data, "gemm_a_bt_acc F={f} t={t}");
        }
    }
}

/// The sparse-feature forward/backward pair is bit-identical across
/// variants (specialization key = the dense output width).
#[test]
fn sparse_feat_bitwise_across_variants() {
    let mut rng = Rng::new(0xC0DE);
    let (n, fin) = (220usize, 180usize);
    let xd = Matrix::from_vec(n, fin, random_sparse_matrix(&mut rng, n, fin, 0.9));
    let csr = CsrMatrix::from_dense(&xd);
    let csc = CscMatrix::from_dense(&xd);
    for h in WIDTHS {
        let w = Matrix::from_vec(fin, h, random_matrix(&mut rng, fin, h));
        let gr = Matrix::from_vec(n, h, random_matrix(&mut rng, n, h));
        for t in THREADS {
            let (pg, ps, _) = policies(t);
            let mut yg = Matrix::zeros(n, h);
            let mut ys = Matrix::zeros(n, h);
            spmm_csr_dense_ex(&csr, &w, &mut yg, pg);
            spmm_csr_dense_ex(&csr, &w, &mut ys, ps);
            assert_eq!(yg.data, ys.data, "csr_dense H={h} t={t}");

            let mut dwg = Matrix::zeros(fin, h);
            let mut dws = Matrix::zeros(fin, h);
            spmm_csc_t_dense_ex(&csc, &gr, &mut dwg, pg);
            spmm_csc_t_dense_ex(&csc, &gr, &mut dws, ps);
            assert_eq!(dwg.data, dws.data, "csc_t_dense H={h} t={t}");
        }
    }
}

fn tiny_spec(name: &'static str, sparsity: f64) -> morphling::graph::DatasetSpec {
    morphling::graph::DatasetSpec {
        name,
        real_nodes: 0,
        real_edges: 0,
        real_features: 0,
        nodes: 180,
        edges: 1100,
        // 32 = paper-default hidden width: the whole model runs on
        // specialized widths, so variant switching touches every layer.
        features: 32,
        classes: 4,
        feat_sparsity: sparsity,
        gamma: 2.4,
        components: 1,
    }
}

/// Full training epochs are bit-deterministic across variant choices for
/// every supported architecture, serial and threaded — the acceptance
/// criterion behind "the dispatcher never changes training numerics".
#[test]
fn training_bitwise_identical_across_variants() {
    for (arch, sparsity) in [
        (Arch::Gcn, 0.9),
        (Arch::SageMean, 0.9),
        (Arch::SageMax, 0.3),
    ] {
        let ds = datasets::load(&tiny_spec("variant-det", sparsity));
        let mut reference = NativeEngine::paper_default(&ds, arch, 17)
            .with_threads(1)
            .with_variant(VariantChoice::ForceGeneric);
        let ref_losses: Vec<f64> = (0..3).map(|_| reference.train_epoch(&ds).loss).collect();
        for t in THREADS {
            for choice in [
                VariantChoice::ForceGeneric,
                VariantChoice::ForceSpecialized,
                VariantChoice::Auto,
            ] {
                let mut eng = NativeEngine::paper_default(&ds, arch, 17)
                    .with_threads(t)
                    .with_variant(choice);
                for (e, &expect) in ref_losses.iter().enumerate() {
                    let got = eng.train_epoch(&ds).loss;
                    assert_eq!(
                        expect.to_bits(),
                        got.to_bits(),
                        "{}: epoch {e} loss diverged at threads={t} kernels={}",
                        arch.name(),
                        choice.name()
                    );
                }
                assert_eq!(
                    reference.params.layers[0].w.data, eng.params.layers[0].w.data,
                    "{}: weights diverged at threads={t} kernels={}",
                    arch.name(),
                    choice.name()
                );
            }
        }
    }
}

fn sample_manifest() -> TuneManifest {
    let mut m = TuneManifest::new();
    m.gammas.insert(1, 0.21);
    m.gammas.insert(4, 0.34);
    // A mixed set of winners so round-trip equality is decision-sensitive.
    for (i, op) in Op::ALL.into_iter().enumerate() {
        m.entries.push(TuneEntry {
            op,
            bucket: SizeBucket::Small,
            width: 32,
            threads: 1,
            variant: if i % 2 == 0 {
                KernelVariant::Specialized
            } else {
                KernelVariant::Generic
            },
            kblock: (op == Op::Gemm).then_some(128),
            generic_secs: 1.5e-3,
            specialized_secs: 1.2e-3,
        });
    }
    m
}

/// For a fixed manifest the dispatcher's decisions are a pure function of
/// (op, stats, choice, threads): repeated resolution never flips, measured
/// cells follow the manifest, unmeasured cells follow the heuristic.
#[test]
fn dispatcher_decisions_stable_for_fixed_manifest() {
    let manifest = sample_manifest();
    let d = Dispatcher::with_manifest(manifest.clone());
    let stats = InputStats::new(1_000, 8_000, 32);
    for op in Op::ALL {
        let expect = manifest.lookup(op, SizeBucket::Small, 32, 1).unwrap().variant;
        for _ in 0..3 {
            assert_eq!(
                d.resolve(op, stats, VariantChoice::Auto, 1),
                expect,
                "{} decision flipped",
                op.as_str()
            );
        }
        // Unmeasured thread count → heuristic (width 32 is covered).
        assert_eq!(
            d.resolve(op, stats, VariantChoice::Auto, 4),
            KernelVariant::Specialized
        );
    }
    assert_eq!(d.kblock(stats, 1), 128);
    assert_eq!(d.gamma(1), Some(0.21));
    assert_eq!(d.gamma(2), None);
}

/// Manifest write → load round trip: the file reproduces the manifest
/// exactly, and a dispatcher over the loaded copy makes identical decisions
/// across the full (op × width × choice × threads) grid.
#[test]
fn manifest_roundtrip_preserves_decisions() {
    let manifest = sample_manifest();
    let path = std::env::temp_dir().join("morphling_tune_roundtrip.json");
    manifest.save(&path).expect("save manifest");
    let loaded = TuneManifest::load(&path).expect("load manifest");
    std::fs::remove_file(&path).ok();
    assert_eq!(manifest, loaded);

    let d1 = Dispatcher::with_manifest(manifest);
    let d2 = Dispatcher::with_manifest(loaded);
    for op in Op::ALL {
        for rows in [100usize, 5_000, 50_000] {
            for width in [16usize, 32, 100] {
                let stats = InputStats::new(rows, rows * 8, width);
                for choice in [
                    VariantChoice::Auto,
                    VariantChoice::ForceGeneric,
                    VariantChoice::ForceSpecialized,
                ] {
                    for threads in [1usize, 4] {
                        assert_eq!(
                            d1.resolve(op, stats, choice, threads),
                            d2.resolve(op, stats, choice, threads),
                            "{} rows={rows} width={width} threads={threads}",
                            op.as_str()
                        );
                    }
                }
                assert_eq!(d1.kblock(stats, 1), d2.kblock(stats, 1));
            }
        }
    }
}

/// ForceSpecialized on an uncovered width is a silent generic fallback —
/// never a panic — end to end through an engine epoch (features = 40 and
/// hidden = 32 mix covered and uncovered widths in one model).
#[test]
fn uncovered_width_falls_back_inside_training() {
    let spec = morphling::graph::DatasetSpec {
        features: 40,
        ..tiny_spec("variant-fallback", 0.5)
    };
    let ds = datasets::load(&spec);
    let mut gen = NativeEngine::paper_default(&ds, Arch::Gcn, 5)
        .with_variant(VariantChoice::ForceGeneric);
    let mut spec_eng = NativeEngine::paper_default(&ds, Arch::Gcn, 5)
        .with_variant(VariantChoice::ForceSpecialized);
    for _ in 0..2 {
        let a = gen.train_epoch(&ds).loss;
        let b = spec_eng.train_epoch(&ds).loss;
        assert_eq!(a.to_bits(), b.to_bits());
    }
    assert!(specialized::has_width(32) && !specialized::has_width(40));
}
