//! Historical-embedding cache invariants (ISSUE 5 acceptance criteria):
//!
//! 1. **Exactness at K = 0** — `--cache --cache-staleness 0` is
//!    bitwise-identical to the cache-off mini-batch path for all three
//!    sampled architectures (losses AND trained weights);
//! 2. **Monotone freshness** — the per-epoch gate's fresh set is nested as
//!    the staleness bound grows, and the engine's hit counters respect the
//!    bound (zero at K = 0, positive from the second epoch at K ≥ 1, mean
//!    staleness ≤ K);
//! 3. **Determinism** — with the cache enabled, training stays
//!    bit-deterministic across kernel thread counts and prefetch on/off;
//! 4. **Evaluation purity** — evaluation neither consults nor perturbs the
//!    store.

use morphling::cache::HistCache;
use morphling::engine::{Engine, Mask};
use morphling::graph::datasets;
use morphling::model::{Arch, GnnParams};
use morphling::sampler::{MiniBatchConfig, MiniBatchEngine};
use morphling::tensor::Matrix;
use morphling::util::Rng;

fn tiny_spec() -> morphling::graph::DatasetSpec {
    morphling::graph::DatasetSpec {
        name: "tiny-cache-it",
        real_nodes: 0,
        real_edges: 0,
        real_features: 0,
        nodes: 240,
        edges: 1600,
        features: 44,
        classes: 5,
        feat_sparsity: 0.0,
        gamma: 2.4,
        components: 1,
    }
}

/// Every trainable buffer, flattened for bitwise comparison.
fn param_bits(p: &GnnParams) -> Vec<f32> {
    let mut out = Vec::new();
    for l in &p.layers {
        out.extend_from_slice(&l.w.data);
        if let Some(ws) = &l.w_self {
            out.extend_from_slice(&ws.data);
        }
        out.extend_from_slice(&l.b);
    }
    out
}

fn engine(ds: &morphling::graph::Dataset, arch: Arch, cache: Option<u64>) -> MiniBatchEngine {
    let cfg = MiniBatchConfig {
        batch_size: 64,
        fanouts: vec![3, 5],
        prefetch: true,
        cache,
    };
    MiniBatchEngine::paper_default(ds, arch, cfg, 11).unwrap()
}

/// K = 0 keeps the cache primed but never serves: the gate is empty, no
/// block grows a cached partition, and the run is bitwise-identical to the
/// cache-off path — the exactness contract that makes `--cache` safe to
/// leave on.
#[test]
fn staleness_zero_bitwise_identical_to_cache_off() {
    let ds = datasets::load(&tiny_spec());
    for arch in [Arch::Gcn, Arch::SageMean, Arch::SageMax] {
        let mut off = engine(&ds, arch, None);
        let mut on = engine(&ds, arch, Some(0));
        for e in 0..3 {
            let (so, sn) = (off.train_epoch(&ds), on.train_epoch(&ds));
            assert_eq!(so.loss, sn.loss, "{} epoch {e}: loss diverged", arch.name());
            assert_eq!(
                param_bits(off.params()),
                param_bits(on.params()),
                "{} epoch {e}: params diverged",
                arch.name()
            );
            assert_eq!(
                off.sampled_edges_last_epoch(),
                on.sampled_edges_last_epoch(),
                "{} epoch {e}: K=0 must not prune sampling",
                arch.name()
            );
        }
        // K = 0 admits nothing: the engine reports all-miss counters.
        let stats = on.cache_stats_last_epoch().unwrap();
        assert_eq!(stats.hits, 0);
        assert!(stats.candidates > 0, "frontier candidates must be counted");
        assert_eq!(stats.hit_rate(), 0.0);
        let (lo, ao) = off.evaluate(&ds, Mask::Val);
        let (ln, an) = on.evaluate(&ds, Mask::Val);
        assert_eq!((lo, ao), (ln, an), "{}: eval diverged", arch.name());
    }
}

/// Gate-level monotonicity: with identical store contents, the fresh set
/// under bound K is a subset of the fresh set under any K' > K, at every
/// level and every query epoch (the property behind "a larger staleness
/// budget can only serve more").
#[test]
fn gate_freshness_nested_in_staleness_bound() {
    let n = 64;
    let mut rng = Rng::new(9);
    // One shared stamp history, replayed into stores with different bounds.
    let history: Vec<(usize, u32, u64)> = (0..200)
        .map(|_| (rng.below(2), rng.below(n) as u32, 1 + rng.below(7) as u64))
        .collect();
    let caches: Vec<HistCache> = (0..6u64)
        .map(|k| {
            let mut c = HistCache::new(n, &[8, 4], k);
            let row = Matrix::zeros(1, 8);
            let row2 = Matrix::zeros(1, 4);
            for &(lvl, id, epoch) in &history {
                c.push(lvl, &[id], if lvl == 0 { &row } else { &row2 }, epoch);
            }
            c
        })
        .collect();
    for epoch in 1..10u64 {
        for w in caches.windows(2) {
            let (small, big) = (w[0].gate(epoch), w[1].gate(epoch));
            for lvl in 0..2 {
                for v in 0..n {
                    assert!(
                        !small.level(lvl)[v] || big.level(lvl)[v],
                        "epoch {epoch} level {lvl} node {v}: fresh set not nested"
                    );
                }
                assert!(small.fresh_count(lvl) <= big.fresh_count(lvl));
            }
        }
        // K = 0 must be empty at any epoch.
        assert_eq!(caches[0].gate(epoch).fresh_count(0), 0);
        assert_eq!(caches[0].gate(epoch).fresh_count(1), 0);
    }
}

/// Engine-level counters respect the bound: epoch 1 has no servable rows
/// (the store is empty at the epoch-1 gate freeze), hits appear from epoch
/// 2 at K ≥ 1, served staleness never exceeds K, and pruning can only
/// shrink the sampled edge volume relative to the cache-off twin.
#[test]
fn cache_hits_bounded_staleness_and_edge_reduction() {
    let ds = datasets::load(&tiny_spec());
    let k = 2u64;
    let mut off = engine(&ds, Arch::SageMean, None);
    let mut on = engine(&ds, Arch::SageMean, Some(k));
    let mut total_off = 0u64;
    let mut total_on = 0u64;
    for e in 1..=4u64 {
        off.train_epoch(&ds);
        on.train_epoch(&ds);
        let (eo, en) = (off.sampled_edges_last_epoch(), on.sampled_edges_last_epoch());
        let stats = on.cache_stats_last_epoch().unwrap();
        assert!(
            en <= eo,
            "epoch {e}: cache-on sampled {en} edges > cache-off {eo}"
        );
        if e == 1 {
            assert_eq!(stats.hits, 0, "no rows are servable before epoch 2");
            assert_eq!(en, eo, "epoch 1 must match the cache-off path exactly");
        } else {
            assert!(stats.hits > 0, "epoch {e}: expected cache hits at K={k}");
            assert!(stats.hits <= stats.candidates);
            let rate = stats.hit_rate();
            assert!(rate > 0.0 && rate <= 1.0, "epoch {e}: hit rate {rate}");
            let mean = stats.mean_staleness();
            assert!(
                mean <= k as f64,
                "epoch {e}: mean staleness {mean} exceeds bound {k}"
            );
        }
        total_off += eo;
        total_on += en;
    }
    assert!(
        total_on < total_off,
        "pruning never engaged: {total_on} vs {total_off} sampled edges"
    );
    assert!(on.cache_bytes() > 0, "store must charge static bytes");
    assert!(off.cache_bytes() == 0);
}

/// Two runs that differ only in the staleness bound share their first two
/// epochs bit-for-bit (the epoch-2 gate admits exactly the epoch-1 stamps
/// for every K ≥ 1), and a K = 0 run serves strictly less — the engine-level
/// face of the gate-nesting property.
#[test]
fn epoch_two_hits_agree_across_positive_bounds() {
    let ds = datasets::load(&tiny_spec());
    let hits_at_epoch_two = |k: u64| {
        let mut eng = engine(&ds, Arch::Gcn, Some(k));
        eng.train_epoch(&ds);
        eng.train_epoch(&ds);
        let s = eng.cache_stats_last_epoch().unwrap();
        (s.hits, s.candidates, param_bits(eng.params()))
    };
    let (h1, c1, p1) = hits_at_epoch_two(1);
    let (h2, c2, p2) = hits_at_epoch_two(2);
    let (h4, c4, p4) = hits_at_epoch_two(4);
    assert!(h1 > 0, "expected hits at epoch 2");
    assert_eq!((h1, c1), (h2, c2), "epoch-2 gates are identical for K >= 1");
    assert_eq!((h1, c1), (h4, c4));
    assert_eq!(p1, p2, "epoch-2 params must agree for K >= 1");
    assert_eq!(p1, p4);
    let (h0, _, _) = hits_at_epoch_two(0);
    assert_eq!(h0, 0, "K = 0 must never serve");
}

/// Full cache-on training is bit-deterministic across kernel thread counts
/// and prefetch on/off: the gate is frozen per epoch, pushes happen only on
/// the training thread, and stitching is row-owned copying.
#[test]
fn cache_training_bit_deterministic_across_threads_and_prefetch() {
    let ds = datasets::load(&tiny_spec());
    let run = |threads: usize, prefetch: bool| {
        let cfg = MiniBatchConfig {
            batch_size: 64,
            fanouts: vec![3, 5],
            prefetch,
            cache: Some(2),
        };
        let mut eng = MiniBatchEngine::paper_default(&ds, Arch::SageMean, cfg, 7)
            .unwrap()
            .with_threads(threads);
        let losses: Vec<f64> = (0..3).map(|_| eng.train_epoch(&ds).loss).collect();
        let stats = eng.cache_stats_last_epoch().unwrap();
        (losses, param_bits(eng.params()), stats)
    };
    let (l_ref, p_ref, s_ref) = run(1, true);
    assert!(s_ref.hits > 0, "cache must engage for the test to bite");
    for (t, p) in [(4usize, true), (1, false), (4, false)] {
        let (l, w, s) = run(t, p);
        assert_eq!(l_ref, l, "losses diverged at threads={t} prefetch={p}");
        assert_eq!(p_ref, w, "weights diverged at threads={t} prefetch={p}");
        assert_eq!(s_ref, s, "cache counters diverged at threads={t} prefetch={p}");
    }
}

/// Evaluation is exact and side-effect free with the cache enabled: it
/// never serves stale rows (full-neighborhood blocks carry no cached
/// partition), never refreshes the store, and leaves the training
/// trajectory untouched.
#[test]
fn evaluation_ignores_and_preserves_the_store() {
    let ds = datasets::load(&tiny_spec());
    // Twin runs: one evaluates between epochs, one doesn't.
    let mut plain = engine(&ds, Arch::SageMean, Some(2));
    let mut evald = engine(&ds, Arch::SageMean, Some(2));
    for _ in 0..3 {
        plain.train_epoch(&ds);
        evald.train_epoch(&ds);
        let a = evald.evaluate(&ds, Mask::Val);
        let b = evald.evaluate(&ds, Mask::Val);
        assert_eq!(a, b, "repeated evaluation must be pure");
    }
    assert_eq!(
        param_bits(plain.params()),
        param_bits(evald.params()),
        "interleaved evaluation perturbed training"
    );
    assert_eq!(
        plain.cache_stats_last_epoch().unwrap(),
        evald.cache_stats_last_epoch().unwrap(),
        "evaluation leaked into the cache counters"
    );
}
